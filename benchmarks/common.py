"""Shared benchmark utilities: timing, CSV emission, smoke mode.

``benchmarks.run --smoke`` flips :data:`SMOKE` before any suite runs;
each suite consults it to shrink shapes/grids/reps so the whole harness
finishes in CI seconds — the point is that benchmark SCRIPTS cannot rot,
not that smoke numbers mean anything.  ``--csv PATH`` tees every
``emit`` row to a file (uploaded as a CI artifact).

``--bench-json PATH`` additionally collects the structured
legacy-vs-new kernel records (kernel_bench / conv_bench layer rows)
into a JSON artifact — the pinned ``BENCH_kernels.json`` trajectory
that ``tools/check_bench.py`` gates in CI (ISSUE 6).
"""
from __future__ import annotations

import pathlib
import time
from typing import Callable, Optional, TextIO

import jax

#: True under ``benchmarks.run --smoke``: tiny shapes, 1 warmup / 1 rep.
SMOKE = False

_CSV: Optional[TextIO] = None

#: collected structured records when ``--bench-json`` is active
_JSON: Optional[list] = None


def set_smoke(on: bool) -> None:
    global SMOKE
    SMOKE = on


def set_csv(fh: Optional[TextIO]) -> None:
    global _CSV
    _CSV = fh


def set_json(records: Optional[list]) -> None:
    global _JSON
    _JSON = records


def add_record(rec: dict) -> None:
    """Append one structured record to the --bench-json collection
    (no-op when JSON collection is off)."""
    if _JSON is not None:
        _JSON.append(rec)


def bench_tune_cache():
    """The repo's committed autotune cache (``tune_cache.json`` at the
    repo root, filled by ``python -m repro.tune``) — empty cache when the
    file is absent, so benches degrade to fallback tiles."""
    from repro.tune.cache import TuneCache
    p = pathlib.Path(__file__).resolve().parent.parent / "tune_cache.json"
    return TuneCache.load(str(p))


def bench_reps(warmup: int = 2, iters: int = 5) -> dict:
    """Requested reps, collapsed to (1, 1) in smoke mode."""
    return ({"warmup": 1, "iters": 1} if SMOKE
            else {"warmup": warmup, "iters": iters})


def time_call(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median microseconds per call (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def time_pair(fn_a: Callable, fn_b: Callable, warmup: int = 1,
              iters: int = 5) -> tuple:
    """Interleaved median microseconds for two rival zero-arg callables.

    Timing the rivals in separate blocks puts any machine drift (CPU
    contention, thermal ramps) entirely on the a/b RATIO — exactly the
    number the legacy-vs-new layer rows gate on.  Alternating a/b
    samples makes drift hit both sides equally instead.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn_a())
        jax.block_until_ready(fn_b())
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        tb.append(time.perf_counter() - t0)
    ta.sort()
    tb.sort()
    return ta[len(ta) // 2] * 1e6, tb[len(tb) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    print(row, flush=True)
    if _CSV is not None:
        _CSV.write(row + "\n")
        _CSV.flush()
