"""Shared benchmark utilities: timing, CSV emission, smoke mode.

``benchmarks.run --smoke`` flips :data:`SMOKE` before any suite runs;
each suite consults it to shrink shapes/grids/reps so the whole harness
finishes in CI seconds — the point is that benchmark SCRIPTS cannot rot,
not that smoke numbers mean anything.  ``--csv PATH`` tees every
``emit`` row to a file (uploaded as a CI artifact).
"""
from __future__ import annotations

import time
from typing import Callable, Optional, TextIO

import jax

#: True under ``benchmarks.run --smoke``: tiny shapes, 1 warmup / 1 rep.
SMOKE = False

_CSV: Optional[TextIO] = None


def set_smoke(on: bool) -> None:
    global SMOKE
    SMOKE = on


def set_csv(fh: Optional[TextIO]) -> None:
    global _CSV
    _CSV = fh


def bench_reps(warmup: int = 2, iters: int = 5) -> dict:
    """Requested reps, collapsed to (1, 1) in smoke mode."""
    return ({"warmup": 1, "iters": 1} if SMOKE
            else {"warmup": warmup, "iters": iters})


def time_call(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median microseconds per call (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    print(row, flush=True)
    if _CSV is not None:
        _CSV.write(row + "\n")
        _CSV.flush()
