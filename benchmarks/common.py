"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_call(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median microseconds per call (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
