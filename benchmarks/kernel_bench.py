"""E6 — BFP kernel microbench + datapath sizing check (paper Fig. 2).

On CPU the Pallas kernel runs in interpret mode (orders of magnitude
slower than compiled TPU); the emulated-int path is the meaningful CPU
number.  Reports us/call and the effective GEMM rate.

E15 (ISSUE 6): per canonical GEMM layer shape (repro.tune.shapes),
the LEGACY kernel datapath (int32-widened dots, no K-pipeline, fallback
tiles) vs the NEW one (resolved dot mode + pipelined K-loop + autotuned
tiles from the committed tune_cache.json).  Same interpret mode, same
shapes, bit-identical outputs — the us ratio is the claim.  Rows land in
the ``--bench-json`` artifact gated by tools/check_bench.py.
"""
from __future__ import annotations

import jax

from repro.core import bfp
from repro.core.bfp_dot import bfp_matmul_2d
from repro.core.policy import BFPPolicy, PAPER_DEFAULT, TPU_TILED
from repro.core.bfp import Scheme
from repro.core.prequant import prequant_act
from repro.tune.cache import use_cache
from repro.tune.shapes import GEMM_LAYERS
from repro.tune.tables import fallback_tiles
from benchmarks import common
from benchmarks.common import (add_record, bench_reps, bench_tune_cache,
                               emit, time_call, time_pair)


def run():
    key = jax.random.PRNGKey(0)
    b, k, n = (64, 256, 64) if common.SMOKE else (256, 1024, 256)
    x = jax.random.normal(key, (b, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.1
    flops = 2 * b * k * n
    reps = bench_reps()

    f_float = jax.jit(lambda x, w: x @ w)
    us = time_call(f_float, x, w, **reps)
    emit("kernel/float_matmul", us, f"GFLOPs={flops / us / 1e3:.1f}")

    for name, pol in (("eq4", PAPER_DEFAULT), ("tiled128", TPU_TILED)):
        pol = pol.with_(straight_through=False)
        f = jax.jit(lambda x, w, pol=pol: bfp_matmul_2d(x, w, pol))
        us = time_call(f, x, w, **reps)
        emit(f"kernel/bfp_emulated_{name}", us,
             f"GFLOPs={flops / us / 1e3:.1f}")

    from repro.kernels import ops
    f = lambda x, w: ops.bfp_matmul(x, w, TPU_TILED, interpret=True)
    us = time_call(f, x, w, **bench_reps(warmup=1, iters=2))
    emit("kernel/bfp_pallas_interpret", us, "CPU-interpret (TPU target)")

    # datapath sizing table (paper Fig. 2)
    for lw, li, kk in ((8, 8, 1152), (8, 8, 4608), (6, 6, 4608)):
        emit(f"kernel/acc_bits_LW{lw}_LI{li}_K{kk}", 0.0,
             f"acc_bits={bfp.accumulator_bits(lw, li, kk)};"
             f"max_safe_k_int32={bfp.max_safe_k(lw, li)}")

    layer_rows()


def layer_rows():
    """E15 legacy-vs-new GEMM rows on the canonical layer shapes."""
    from repro.kernels import ops
    reps = bench_reps(warmup=1, iters=3)
    cache = bench_tune_cache()
    base = BFPPolicy(scheme=Scheme.TILED, block_k=128,
                     straight_through=False)
    for i, (name, b, k, n) in enumerate(GEMM_LAYERS):
        if common.SMOKE:
            b, k, n = min(b, 128), min(k, 256), min(n, 128)
        # same block policy the tune CLI uses, so lookups hit its entries
        pol = base if k % 128 == 0 else base.with_(block_k=None)
        key = jax.random.PRNGKey(i)
        x = jax.random.normal(key, (b, k))
        w = jax.random.normal(jax.random.fold_in(key, 1), (k, n)) * 0.1

        legacy = lambda: ops.bfp_matmul(x, w, pol, True, dot_impl="int32",
                                        pipeline=False)

        def new():
            # cache scope inside the callable: the interleaved rival
            # (legacy) must keep its fallback tiles
            with use_cache(cache):
                return ops.bfp_matmul(x, w, pol, True)

        us_legacy, us_new = time_pair(legacy, new, **reps)
        with use_cache(cache):
            tiles_new = ops._gemm_tiles(b, k, n, pol, True, None, None)
        tiles_legacy = fallback_tiles(b, k, n, pol.block_k)

        # fused requantize epilogue vs dequantize-then-requantize (the
        # HBM-traffic trade; bit-identical, pinned by tests)
        out_pol = base.with_(block_k=8)
        fused = lambda: ops.bfp_matmul(x, w, pol, True, out_policy=out_pol)
        twostep = lambda: prequant_act(
            ops.bfp_matmul(x, w, pol, True), out_pol)
        with use_cache(cache):
            us_fused, us_twostep = time_pair(fused, twostep, **reps)

        hbm = (b * k + k * n + b * n) * 4
        emit(f"kernel/{name}/legacy", us_legacy, f"tiles={tiles_legacy}")
        emit(f"kernel/{name}/new", us_new,
             f"tiles={tiles_new};speedup={us_legacy / us_new:.2f}x")
        add_record({
            "kind": "gemm", "name": name, "shape": [b, k, n],
            "l_i": pol.l_i, "l_w": pol.l_w, "block_k": pol.block_k,
            "hbm_bytes": hbm,
            "tokens_per_s": round(b / us_new * 1e6, 1),
            "legacy": {"us": round(us_legacy, 1), "dot_impl": "int32",
                       "pipeline": False, "tiles": list(tiles_legacy)},
            "new": {"us": round(us_new, 1), "dot_impl": "auto",
                    "pipeline": True, "tiles": list(tiles_new)},
            "speedup": round(us_legacy / us_new, 3),
            "epilogue": {
                "us_fused": round(us_fused, 1),
                "us_twostep": round(us_twostep, 1),
                # f32 activation round-trip vs int8 mantissa + f32 steps
                "act_bytes_f32": b * n * 4,
                "act_bytes_wire": b * n + 4 * (b * n // 8)},
        })


if __name__ == "__main__":
    run()
