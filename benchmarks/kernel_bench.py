"""E6 — BFP kernel microbench + datapath sizing check (paper Fig. 2).

On CPU the Pallas kernel runs in interpret mode (orders of magnitude
slower than compiled TPU); the emulated-int path is the meaningful CPU
number.  Reports us/call and the effective GEMM rate.
"""
from __future__ import annotations

import jax

from repro.core import bfp
from repro.core.bfp_dot import bfp_matmul_2d
from repro.core.policy import PAPER_DEFAULT, TPU_TILED
from benchmarks import common
from benchmarks.common import bench_reps, emit, time_call


def run():
    key = jax.random.PRNGKey(0)
    b, k, n = (64, 256, 64) if common.SMOKE else (256, 1024, 256)
    x = jax.random.normal(key, (b, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.1
    flops = 2 * b * k * n
    reps = bench_reps()

    f_float = jax.jit(lambda x, w: x @ w)
    us = time_call(f_float, x, w, **reps)
    emit("kernel/float_matmul", us, f"GFLOPs={flops / us / 1e3:.1f}")

    for name, pol in (("eq4", PAPER_DEFAULT), ("tiled128", TPU_TILED)):
        pol = pol.with_(straight_through=False)
        f = jax.jit(lambda x, w, pol=pol: bfp_matmul_2d(x, w, pol))
        us = time_call(f, x, w, **reps)
        emit(f"kernel/bfp_emulated_{name}", us,
             f"GFLOPs={flops / us / 1e3:.1f}")

    from repro.kernels import ops
    f = lambda x, w: ops.bfp_matmul(x, w, TPU_TILED, interpret=True)
    us = time_call(f, x, w, **bench_reps(warmup=1, iters=2))
    emit("kernel/bfp_pallas_interpret", us, "CPU-interpret (TPU target)")

    # datapath sizing table (paper Fig. 2)
    for lw, li, kk in ((8, 8, 1152), (8, 8, 4608), (6, 6, 4608)):
        emit(f"kernel/acc_bits_LW{lw}_LI{li}_K{kk}", 0.0,
             f"acc_bits={bfp.accumulator_bits(lw, li, kk)};"
             f"max_safe_k_int32={bfp.max_safe_k(lw, li)}")


if __name__ == "__main__":
    run()
