"""Benchmark harness — one module per paper table/figure + system extras.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).

  table1_storage       paper Table 1 (scheme storage costs) + MEASURED
                       packed-container / checkpoint bytes (ISSUE 5)
  table2_scheme        paper Table 2 (eq.2 vs eq.4 accuracy, no retrain)
  table3_sweep         paper Table 3 (L_W x L_I accuracy-drop grid) + E5
  table4_nsr           paper Table 4 (per-layer SNR: measured vs model)
  kernel_bench         E6 kernel microbench + Fig. 2 datapath sizing
  blocksize_ablation   E10 TPU K-tile block-size ablation (beyond paper)
  engine_bench         E11 engine: cached prequant weights vs per-step
                       re-quantization (ISSUE 1 acceptance)
  conv_bench           E12 fused implicit-im2col conv vs im2col+GEMM
                       (ISSUE 2 acceptance)
  dispatch_bench       E13 bound-plan vs per-call dispatch (trace time +
                       eager steady state; ISSUE 3 acceptance)
  cnn_serve_bench      E14 CNN serving: requests/sec vs batch bucket
                       size + prequant on/off (ISSUE 4 acceptance)
  faults_bench         E15 fault endurance: NSR / top-1 agreement vs
                       bit-error rate x L x target (ISSUE 7 acceptance)
  cnn_train            E16 BFP train-to-accuracy: quantized backward
                       GEMMs + compressed gradient exchange at L=4..12
                       vs float baseline (ISSUE 8 acceptance)
  serve_load           E17 open-loop Poisson serving load: continuous
                       vs bucket-barrier batching, p50/p99/goodput +
                       overload behaviour (ISSUE 9 acceptance).  Its
                       pinned trajectory lives in BENCH_serve.json,
                       written by ``python -m benchmarks.serve_load
                       --bench-json`` (own schema, own CI gate)
  pack_bench           E18 packed-container bytes: fixed-L vs
                       variable-width (ISSUE 10 acceptance).  Pinned
                       trajectory in BENCH_pack.json, written by
                       ``python -m benchmarks.pack_bench --bench-json``
                       (own schema ``pack-1``, own CI gate)

Flags:
  --smoke       tiny shapes, 1 rep — CI rot-check mode (the numbers are
                meaningless; the scripts running end-to-end is the point)
  --csv PATH    tee every emitted row to PATH (CI uploads it)
  --bench-json PATH
                collect the structured legacy-vs-new kernel records
                (kernel/conv layer rows) into PATH.  The committed
                BENCH_kernels.json at the repo root is this artifact from
                a full (non-smoke) run; CI regenerates it and
                tools/check_bench.py fails on a >20% speedup regression
                (ratios are machine-independent; absolute us are not)

Roofline/dry-run numbers are produced by ``repro.launch.dryrun`` (they
need the 512-device env) and summarized in EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

from benchmarks import (blocksize_ablation, cnn_serve_bench, cnn_train,
                        common, conv_bench, dispatch_bench, engine_bench,
                        faults_bench, kernel_bench, pack_bench, serve_load,
                        table1_storage, table2_scheme, table3_sweep,
                        table4_nsr)

_ALL = {
    "table1": table1_storage.run,
    "table2": table2_scheme.run,
    "table3": table3_sweep.run,
    "table4": table4_nsr.run,
    "kernel": kernel_bench.run,
    "blocksize": blocksize_ablation.run,
    "engine": engine_bench.run,
    "conv": conv_bench.run,
    "dispatch": dispatch_bench.run,
    "cnn_serve": cnn_serve_bench.run,
    "faults": faults_bench.run,
    "cnn_train": cnn_train.run,
    "serve_load": serve_load.run,
    "pack": pack_bench.run,
}


def main() -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.run")
    ap.add_argument("names", nargs="*", metavar="suite",
                    help=f"suites to run (default: all of {list(_ALL)})")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / 1 rep (CI rot check)")
    ap.add_argument("--csv", metavar="PATH",
                    help="also write CSV rows to PATH")
    ap.add_argument("--bench-json", metavar="PATH",
                    help="write structured legacy-vs-new kernel records "
                         "(kernel/conv suites) to PATH")
    args = ap.parse_args()
    names = args.names or list(_ALL)
    unknown = [n for n in names if n not in _ALL]
    if unknown:
        ap.error(f"unknown suite(s) {unknown}; available: {list(_ALL)}")
    common.set_smoke(args.smoke)
    fh = open(args.csv, "w") if args.csv else None
    common.set_csv(fh)
    records = [] if args.bench_json else None
    common.set_json(records)

    print("name,us_per_call,derived")
    if fh:
        fh.write("name,us_per_call,derived\n")
    failures = 0
    for n in names:
        t0 = time.time()
        try:
            _ALL[n]()
        except Exception:
            failures += 1
            traceback.print_exc()
        print(f"# {n} done in {time.time() - t0:.1f}s", flush=True)
    if fh:
        fh.close()
    if args.bench_json:
        doc = {"schema": 1, "mode": "smoke" if args.smoke else "full",
               "target": "interpret", "records": records}
        with open(args.bench_json, "w") as jf:
            json.dump(doc, jf, indent=1, sort_keys=True)
            jf.write("\n")
        print(f"# wrote {len(records)} records to {args.bench_json}",
              flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
