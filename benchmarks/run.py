"""Benchmark harness — one module per paper table/figure + system extras.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).

  table1_storage       paper Table 1 (scheme storage costs)
  table2_scheme        paper Table 2 (eq.2 vs eq.4 accuracy, no retrain)
  table3_sweep         paper Table 3 (L_W x L_I accuracy-drop grid) + E5
  table4_nsr           paper Table 4 (per-layer SNR: measured vs model)
  kernel_bench         E6 kernel microbench + Fig. 2 datapath sizing
  blocksize_ablation   E10 TPU K-tile block-size ablation (beyond paper)
  engine_bench         E11 engine: cached prequant weights vs per-step
                       re-quantization (ISSUE 1 acceptance)

Roofline/dry-run numbers are produced by ``repro.launch.dryrun`` (they
need the 512-device env) and summarized in EXPERIMENTS.md.
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (blocksize_ablation, engine_bench, kernel_bench,
                        table1_storage, table2_scheme, table3_sweep,
                        table4_nsr)

_ALL = {
    "table1": table1_storage.run,
    "table2": table2_scheme.run,
    "table3": table3_sweep.run,
    "table4": table4_nsr.run,
    "kernel": kernel_bench.run,
    "blocksize": blocksize_ablation.run,
    "engine": engine_bench.run,
}


def main() -> None:
    names = sys.argv[1:] or list(_ALL)
    print("name,us_per_call,derived")
    failures = 0
    for n in names:
        t0 = time.time()
        try:
            _ALL[n]()
        except Exception:
            failures += 1
            traceback.print_exc()
        print(f"# {n} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
