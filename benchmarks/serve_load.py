"""E17 open-loop serving load: continuous vs bucket-barrier batching
under Poisson traffic (ISSUE 9 acceptance: continuous batching beats the
bucket baseline on p99 latency AND goodput under staggered arrivals).

Rows:
  serve_load/<scn>/<mode>/p99_ms        p99 latency (virtual ms)
  serve_load/<scn>/<mode>/goodput       successful requests per second
  serve_load/<scn>/speedup              p99 bucket / p99 continuous
  serve_load/<scn>/goodput_ratio        goodput continuous / bucket
  serve_load/overload/...               degraded-mode behaviour counters

Both modes replay the IDENTICAL seeded arrival trace on a deterministic
virtual clock (``call_cost`` seconds per jitted engine call — one
whole-batch decode step / batched forward is one unit of accelerator
occupancy), so every reported number — and therefore the pinned
``BENCH_serve.json`` ratios ``tools/check_bench.py`` gates — is
machine-independent and exactly reproducible.  The engines still run
their real jitted compute; only the TIMELINE is modeled, because the
quantity under test is the scheduling policy, not the kernel speed
(kernel speed has its own pinned trajectory in ``BENCH_kernels.json``).

Standalone (the CI serve-load-smoke job):

    python -m benchmarks.serve_load --smoke --csv serve.csv \
        --bench-json bench-serve-ci.json
"""
from __future__ import annotations

import argparse
import json
import sys

import jax

from benchmarks import common
from repro.configs.base import reduced
from repro.configs.registry import ARCHS
from repro.core.policy import PAPER_DEFAULT
from repro.models.cnn import MODELS
from repro.serve.cnn import CnnServeEngine, ImageRequest
from repro.serve.degrade import DegradeConfig
from repro.serve.engine import Request, ServeEngine
from repro.serve.load import VirtualClock, poisson_arrivals, run_open_loop
from repro.train.step import init_state

POLICY = PAPER_DEFAULT.with_(straight_through=False)
FALLBACK = POLICY.with_(l_w=4, l_i=4)

#: virtual seconds per jitted engine call — the deterministic timeline
CNN_CALL_COST = 0.004
LM_CALL_COST = 0.002


def _emit_mode(scn: str, mode: str, rep) -> None:
    common.emit(f"serve_load/{scn}/{mode}/p99_ms", rep.p99_ms * 1e3,
                f"p50_ms={rep.p50_ms:.2f}")
    common.emit(
        f"serve_load/{scn}/{mode}/goodput", 0.0,
        f"rps={rep.goodput_rps:.1f} completed={rep.completed} "
        f"expired={rep.expired} shed={rep.shed} calls={rep.calls}")


def _record(scn: str, kind: str, rep_c, rep_b, extra: dict,
            gate_kind: str = None) -> dict:
    """Pin the continuous-vs-bucket ratios.  ``gate_kind`` narrows the
    p99 gate to one request kind — in a mixed workload the aggregate
    p99 belongs to the slowest kind (which pays the same total service
    either way), while the barrier's victims are the kinds queued
    BEHIND it."""
    if gate_kind is not None:
        p99_c = rep_c.kinds[gate_kind]["p99_ms"]
        p99_b = rep_b.kinds[gate_kind]["p99_ms"]
        common.emit(f"serve_load/{scn}/continuous/p99_{gate_kind}_ms",
                    p99_c * 1e3, "")
        common.emit(f"serve_load/{scn}/bucket/p99_{gate_kind}_ms",
                    p99_b * 1e3, "")
    else:
        p99_c, p99_b = rep_c.p99_ms, rep_b.p99_ms
    speedup = p99_b / max(p99_c, 1e-9)
    goodput_ratio = rep_c.goodput_rps / max(rep_b.goodput_rps, 1e-9)
    common.emit(f"serve_load/{scn}/speedup", 0.0,
                f"p99_bucket_over_continuous={speedup:.2f}x"
                + (f" gate_kind={gate_kind}" if gate_kind else ""))
    common.emit(f"serve_load/{scn}/goodput_ratio", 0.0,
                f"continuous_over_bucket={goodput_ratio:.2f}x")
    rec = {"kind": kind, "name": scn,
           "speedup": round(speedup, 4),
           "goodput_ratio": round(goodput_ratio, 4),
           "gate_kind": gate_kind,
           "continuous": rep_c.row(), "bucket": rep_b.row()}
    rec.update(extra)
    common.add_record(rec)
    return rec


def _scenario_cnn() -> dict:
    """lenet under mixed-deadline Poisson traffic, both batching modes."""
    n = 48 if common.SMOKE else 400
    rate, seed = 150.0, 7
    spec = MODELS["lenet"]
    params = spec.init(jax.random.PRNGKey(0))
    imgs = [jax.random.normal(jax.random.PRNGKey(10 + i),
                              spec.input_shape()) for i in range(8)]
    mix = [(0.7, "plain", {}),
           (0.3, "deadline", {"deadline": 0.040})]
    arrivals = poisson_arrivals(rate, n, mix, seed=seed)

    reports = {}
    for mode in ("continuous", "bucket"):
        clock = VirtualClock()
        eng = CnnServeEngine(params, spec.apply, POLICY, slots=8,
                             batching=mode, max_wait=4, clock=clock)

        def mk(a):
            return ImageRequest(
                rid=a.rid, image=imgs[a.rid % len(imgs)],
                deadline=None if a.deadline is None else a.t + a.deadline)

        reports[mode] = run_open_loop(eng, arrivals, mk, clock=clock,
                                      call_cost=CNN_CALL_COST)
        _emit_mode("cnn/lenet", mode, reports[mode])
    return _record("cnn/lenet", "serve_cnn", reports["continuous"],
                   reports["bucket"],
                   {"n": n, "rate": rate, "seed": seed,
                    "call_cost": CNN_CALL_COST})


def _scenario_lm() -> dict:
    """Mixed short/long prompts: chunked prefill vs blocking prefill.

    The long prompts are the point — in bucket mode each long admission
    runs ``len(prompt)`` jitted calls while every in-flight decode (and
    every deadline) waits behind it.
    """
    n = 32 if common.SMOKE else 200
    # ~35% utilization: mean ~17 calls/request at 2ms/call vs 10/s
    # offered — the tail must be STALL-dominated (a short request stuck
    # behind a 32-call blocking prefill), not burst-dominated: a
    # saturated system has horizon-length p99 in BOTH modes, hiding the
    # scheduling difference under test
    rate, seed = 10.0, 11
    cfg = reduced(ARCHS["tinyllama-1.1b"], n_layers=2, d_model=64,
                  d_ff=128, vocab=256)
    params = init_state(cfg, jax.random.PRNGKey(0)).params
    mix = [(0.75, "short", {"plen": 4, "max_new": 6, "deadline": 0.12}),
           (0.25, "long", {"plen": 32, "max_new": 6})]
    arrivals = poisson_arrivals(rate, n, mix, seed=seed)

    reports = {}
    for mode in ("continuous", "bucket"):
        clock = VirtualClock()
        eng = ServeEngine(params, cfg, slots=4, max_len=64, policy=POLICY,
                          batching=mode, prefill_chunk=4, clock=clock)

        def mk(a):
            prompt = [1 + (a.rid + j) % 250
                      for j in range(a.payload["plen"])]
            return Request(
                rid=a.rid, prompt=prompt, max_new=a.payload["max_new"],
                deadline=None if a.deadline is None else a.t + a.deadline)

        reports[mode] = run_open_loop(eng, arrivals, mk, clock=clock,
                                      call_cost=LM_CALL_COST)
        _emit_mode("lm/mixed_prompts", mode, reports[mode])
    return _record("lm/mixed_prompts", "serve_lm", reports["continuous"],
                   reports["bucket"],
                   {"n": n, "rate": rate, "seed": seed,
                    "call_cost": LM_CALL_COST},
                   gate_kind="short")


def _scenario_overload() -> None:
    """Continuous engine far past capacity: shedding, expiry, and the
    lower-L degraded mode must all engage (report-only; the counts are
    deterministic but the interesting gate is that the engine survives)."""
    n = 60 if common.SMOKE else 300
    spec = MODELS["lenet"]
    params = spec.init(jax.random.PRNGKey(0))
    imgs = [jax.random.normal(jax.random.PRNGKey(30 + i),
                              spec.input_shape()) for i in range(4)]
    # 2500/s offered vs ~2000/s capacity (8 slots per 4ms forward):
    # the queue must grow, so shedding, expiry, and the degrade trip
    # all have to engage
    arrivals = poisson_arrivals(
        2500.0, n, [(1.0, "tight", {"deadline": 0.020})], seed=13)
    clock = VirtualClock()
    eng = CnnServeEngine(params, spec.apply, POLICY, slots=8,
                         max_queue=16, fallback_policy=FALLBACK,
                         degrade=DegradeConfig(queue_high=8, queue_low=2,
                                               trip_steps=1,
                                               recover_steps=2),
                         clock=clock)

    def mk(a):
        return ImageRequest(rid=a.rid, image=imgs[a.rid % len(imgs)],
                            deadline=a.t + a.deadline)

    rep = run_open_loop(eng, arrivals, mk, clock=clock,
                        call_cost=CNN_CALL_COST)
    _emit_mode("overload", "continuous", rep)
    common.emit("serve_load/overload/degraded", 0.0,
                f"degraded_served={rep.degraded_served} "
                f"trips={eng.controller.trips}")


def run():
    _scenario_cnn()
    _scenario_lm()
    _scenario_overload()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.serve_load")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--csv", metavar="PATH")
    ap.add_argument("--bench-json", metavar="PATH")
    args = ap.parse_args(argv)
    common.set_smoke(args.smoke)
    fh = open(args.csv, "w") if args.csv else None
    common.set_csv(fh)
    records: list = []
    common.set_json(records)
    print("name,us_per_call,derived")
    if fh:
        fh.write("name,us_per_call,derived\n")
    run()
    if fh:
        fh.close()
    if args.bench_json:
        doc = {"schema": "serve-1",
               "mode": "smoke" if args.smoke else "full",
               "records": records}
        with open(args.bench_json, "w") as jf:
            json.dump(doc, jf, indent=1, sort_keys=True)
            jf.write("\n")
        print(f"# wrote {len(records)} records to {args.bench_json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
