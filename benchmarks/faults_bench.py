"""E15: fault-endurance curves — NSR / top-1 agreement vs bit-error rate.

Runs the seeded fault campaign (``repro.faults.campaign``) over the CNN
registry and emits one CSV row per (model, L, target, BER) cell::

    faults/<model>/L<l>/<target>/ber<ber>, <us_per_call>,
        n_flips=..;agree=..;snr_db=..;nsr=..

``us_per_call`` is the wall time of the faulty forward (injection +
bind + apply) — the campaign's cost, not a kernel number.  The derived
fields are the science: exponent flips collapse the logits (NSR -> inf
at BERs where mantissa LSB flips are still invisible), pinning the
exponent >> mantissa-MSB >> mantissa-LSB severity hierarchy that
DESIGN.md §11.1 documents and tests/test_faults.py asserts.

Smoke mode (CI): lenet only, L=8, one BER per target — the rot check
that the campaign drives end-to-end, plus the severity-ordering sanity
assert at the one BER where all three targets land flips.
"""
from __future__ import annotations

import time

from benchmarks import common
from repro.faults import campaign as C

#: full-run grid; smoke collapses to the first entry of each axis
MODELS_FULL = ("lenet", "cifarnet", "vgg16", "resnet18")
L_FULL = (8, 6, 4)
BERS_FULL = (1e-4, 1e-3, 1e-2)
TARGETS = ("exponent", "mantissa_msb", "mantissa_lsb", "activation")


def run() -> None:
    models = MODELS_FULL[:1] if common.SMOKE else MODELS_FULL
    l_values = L_FULL[:1] if common.SMOKE else L_FULL
    bers = (1e-2,) if common.SMOKE else BERS_FULL
    rows = []
    for model in models:
        for l in l_values:
            for target in TARGETS:
                for ber in bers:
                    t0 = time.perf_counter()
                    r = C.run_point(model, l, target, ber, seed=0,
                                    n_images=2 if common.SMOKE else 8)
                    us = (time.perf_counter() - t0) * 1e6
                    rows.append(r)
                    common.emit(
                        f"faults/{model}/L{l}/{target}/ber{ber:g}", us,
                        f"n_flips={r['n_flips']};"
                        f"agree={r['top1_agree']:.3f};"
                        f"snr_db={r['snr_db']:.2f};nsr={r['nsr']:.4g}")
    # severity hierarchy holds wherever every target landed flips —
    # the campaign's headline result, asserted so the bench rots loudly
    ber = max(bers)
    e = C.mean_nsr(rows, target="exponent", ber=ber)
    msb = C.mean_nsr(rows, target="mantissa_msb", ber=ber)
    lsb = C.mean_nsr(rows, target="mantissa_lsb", ber=ber)
    assert e > msb > lsb, \
        f"severity hierarchy violated: exp={e} msb={msb} lsb={lsb}"
    common.emit(f"faults/hierarchy/ber{ber:g}", 0.0,
                f"exp_nsr={e:.4g};msb_nsr={msb:.4g};lsb_nsr={lsb:.4g}")
