"""E12 — fused implicit-im2col conv vs materialized im2col + GEMM.

The ISSUE-2 acceptance artifact: per layer shape of the paper's models
(VGG-16 / ResNet-18 conv layers), report

  * us/call of the fused conv kernel vs the im2col+GEMM route (both on
    the Pallas backend; interpret mode on CPU, so the RATIO is the
    meaningful number, not the absolute us),
  * MODELED activation HBM bytes both ways.  im2col materializes the
    patch matrix in HBM (one write + one read of B*OH*OW*kh*kw*C floats
    on top of reading x); the fused kernel reads only the padded input.
    The kh*kw-fold patch inflation is exactly the off-chip traffic the
    paper's §3.1 argument says BFP should be cutting.

Spatial dims are scaled down (interpret mode runs the kernel body in
Python); channel counts and kernel/stride geometry are the real layer
shapes, and the bytes model uses the benchmarked shapes consistently.

Run:  PYTHONPATH=src python -m benchmarks.run conv
"""
from __future__ import annotations

import jax

from benchmarks import common
from benchmarks.common import bench_reps, emit, time_call
from repro import engine as EG
from repro.core.bfp import Scheme
from repro.core.conv_utils import conv_geometry
from repro.core.policy import BFPPolicy
from repro.kernels import ops

# (name, in_ch, out_ch, k, stride) — VGG-16 and ResNet-18 conv geometry
_LAYERS = [
    ("vgg16/conv1_1", 3, 64, 3, 1),
    ("vgg16/conv2_1", 64, 128, 3, 1),
    ("vgg16/conv3_1", 128, 256, 3, 1),
    ("vgg16/conv5_3", 512, 512, 3, 1),
    ("resnet18/stem7x7", 3, 64, 7, 2),
    ("resnet18/block_3x3", 64, 64, 3, 1),
    ("resnet18/down_3x3_s2", 128, 256, 3, 2),
]


def _bytes_model(b, h, w, c, kh, kw, stride, padding):
    """Modeled activation HBM bytes (fp32): fused reads the padded input
    once; im2col additionally writes + reads the patch matrix."""
    oh, ow, (pt, pb), (pl, pr) = conv_geometry(h, w, kh, kw, stride,
                                               padding)
    x_bytes = b * (h + pt + pb) * (w + pl + pr) * c * 4
    patch_bytes = b * oh * ow * kh * kw * c * 4
    return x_bytes, x_bytes + 2 * patch_bytes


def run():
    hw = 8 if common.SMOKE else 32
    batch = 1
    reps = bench_reps(warmup=1, iters=3)
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=128,
                    straight_through=False, backend="pallas")
    layers = _LAYERS[:3] if common.SMOKE else _LAYERS
    for i, (name, c, oc, k, stride) in enumerate(layers):
        if common.SMOKE:
            c, oc = min(c, 16), min(oc, 16)
        key = jax.random.PRNGKey(i)
        x = jax.random.normal(key, (batch, hw, hw, c))
        w = jax.random.normal(jax.random.fold_in(key, 1),
                              (k, k, c, oc)) * 0.1

        fused = lambda x, w: ops.bfp_conv2d(x, w, pol, stride, "SAME",
                                            interpret=True)
        im2col = lambda x, w: EG.conv2d_im2col(x, w, pol, stride, "SAME")
        us_fused = time_call(fused, x, w, **reps)
        us_im2col = time_call(im2col, x, w, **reps)
        fused_b, im2col_b = _bytes_model(batch, hw, hw, c, k, k, stride,
                                         "SAME")
        emit(f"conv/{name}/fused", us_fused,
             f"act_bytes={fused_b}")
        emit(f"conv/{name}/im2col_gemm", us_im2col,
             f"act_bytes={im2col_b};bytes_cut={im2col_b / fused_b:.2f}x;"
             f"speedup={us_im2col / us_fused:.2f}x")


if __name__ == "__main__":
    run()
