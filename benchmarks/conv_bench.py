"""E12 — fused implicit-im2col conv vs materialized im2col + GEMM.

The ISSUE-2 acceptance artifact: per layer shape of the paper's models
(VGG-16 / ResNet-18 conv layers), report

  * us/call of the fused conv kernel vs the im2col+GEMM route (both on
    the Pallas backend; interpret mode on CPU, so the RATIO is the
    meaningful number, not the absolute us),
  * MODELED activation HBM bytes both ways.  im2col materializes the
    patch matrix in HBM (one write + one read of B*OH*OW*kh*kw*C floats
    on top of reading x); the fused kernel reads only the padded input.
    The kh*kw-fold patch inflation is exactly the off-chip traffic the
    paper's §3.1 argument says BFP should be cutting.

Spatial dims are scaled down (interpret mode runs the kernel body in
Python); channel counts and kernel/stride geometry are the real layer
shapes, and the bytes model uses the benchmarked shapes consistently.

Run:  PYTHONPATH=src python -m benchmarks.run conv
"""
from __future__ import annotations

import jax

from benchmarks import common
from benchmarks.common import (add_record, bench_reps, bench_tune_cache,
                               emit, time_call, time_pair)
from repro import engine as EG
from repro.core.bfp import Scheme
from repro.core.conv_utils import conv_geometry
from repro.core.policy import BFPPolicy
from repro.kernels import ops
from repro.tune.cache import use_cache
from repro.tune.shapes import CONV_LAYERS
from repro.tune.tables import aligned_tile, conv_row_tile

#: VGG-16 / ResNet-18 conv geometry — the shared canonical table
_LAYERS = list(CONV_LAYERS)


def _bytes_model(b, h, w, c, kh, kw, stride, padding):
    """Modeled activation HBM bytes (fp32): fused reads the padded input
    once; im2col additionally writes + reads the patch matrix."""
    oh, ow, (pt, pb), (pl, pr) = conv_geometry(h, w, kh, kw, stride,
                                               padding)
    x_bytes = b * (h + pt + pb) * (w + pl + pr) * c * 4
    patch_bytes = b * oh * ow * kh * kw * c * 4
    return x_bytes, x_bytes + 2 * patch_bytes


def run():
    hw = 8 if common.SMOKE else 32
    batch = 1
    reps = bench_reps(warmup=1, iters=3)
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=128,
                    straight_through=False, backend="pallas")
    layers = _LAYERS[:3] if common.SMOKE else _LAYERS
    for i, (name, c, oc, k, stride) in enumerate(layers):
        if common.SMOKE:
            c, oc = min(c, 16), min(oc, 16)
        key = jax.random.PRNGKey(i)
        x = jax.random.normal(key, (batch, hw, hw, c))
        w = jax.random.normal(jax.random.fold_in(key, 1),
                              (k, k, c, oc)) * 0.1

        fused = lambda x, w: ops.bfp_conv2d(x, w, pol, stride, "SAME",
                                            interpret=True)
        im2col = lambda x, w: EG.conv2d_im2col(x, w, pol, stride, "SAME")
        us_fused = time_call(fused, x, w, **reps)
        us_im2col = time_call(im2col, x, w, **reps)
        fused_b, im2col_b = _bytes_model(batch, hw, hw, c, k, k, stride,
                                         "SAME")
        emit(f"conv/{name}/fused", us_fused,
             f"act_bytes={fused_b}")
        emit(f"conv/{name}/im2col_gemm", us_im2col,
             f"act_bytes={im2col_b};bytes_cut={im2col_b / fused_b:.2f}x;"
             f"speedup={us_im2col / us_fused:.2f}x")

    layer_rows()


def layer_rows():
    """E15 legacy-vs-new fused-conv rows on the canonical layer shapes
    (same interpret mode and shapes; bit-identical outputs)."""
    hw = 8 if common.SMOKE else 32
    batch = 1
    reps = bench_reps(warmup=1, iters=5)
    cache = bench_tune_cache()
    base = BFPPolicy(scheme=Scheme.TILED, block_k=128,
                     straight_through=False)
    layers = _LAYERS[:3] if common.SMOKE else _LAYERS
    for i, (name, c, oc, k, stride) in enumerate(layers):
        if common.SMOKE:
            c, oc = min(c, 16), min(oc, 16)
        # same per-layer block policy as the tune CLI, so cached tile
        # entries key-match
        pol = base if (k * k * c) % 128 == 0 else \
            base.with_(block_k=c if c <= 128 else None)
        key = jax.random.PRNGKey(100 + i)
        x = jax.random.normal(key, (batch, hw, hw, c))
        w = jax.random.normal(jax.random.fold_in(key, 1),
                              (k, k, c, oc)) * 0.1
        oh, ow, _, _ = conv_geometry(hw, hw, k, k, stride, "SAME")

        legacy = lambda: ops.bfp_conv2d(x, w, pol, stride, "SAME", True,
                                        dot_impl="int32", pipeline=False)

        def new():
            # cache scope inside the callable: the interleaved rival
            # (legacy) must keep its fallback tiles
            with use_cache(cache):
                return ops.bfp_conv2d(x, w, pol, stride, "SAME", True)

        us_legacy, us_new = time_pair(legacy, new, **reps)
        with use_cache(cache):
            t_oh, bn = ops._conv_tiles(batch * oh * ow, k * k * c, oc,
                                       pol, True, None)
        tiles_legacy = [conv_row_tile(oh, ow), aligned_tile(oc)]
        tiles_new = [t_oh or tiles_legacy[0], bn or tiles_legacy[1]]

        x_b, _ = _bytes_model(batch, hw, hw, c, k, k, stride, "SAME")
        hbm = x_b + k * k * c * oc * 4 + batch * oh * ow * oc * 4
        emit(f"conv/{name}/legacy", us_legacy, f"tiles={tiles_legacy}")
        emit(f"conv/{name}/new", us_new,
             f"tiles={tiles_new};speedup={us_legacy / us_new:.2f}x")
        add_record({
            "kind": "conv", "name": name,
            "shape": [batch, hw, hw, c, k, oc, stride],
            "l_i": pol.l_i, "l_w": pol.l_w, "block_k": pol.block_k,
            "hbm_bytes": hbm,
            "tokens_per_s": round(batch * oh * ow / us_new * 1e6, 1),
            "legacy": {"us": round(us_legacy, 1), "dot_impl": "int32",
                       "pipeline": False, "tiles": tiles_legacy},
            "new": {"us": round(us_new, 1), "dot_impl": "auto",
                    "pipeline": True, "tiles": tiles_new},
            "speedup": round(us_legacy / us_new, 3),
        })


if __name__ == "__main__":
    run()
