"""Dispatch cost: bound-plan vs per-call policy resolution (E13).

``engine.bind`` moves PolicyMap regex resolution, registry lookup, and
backend support checks from every ``engine.gemm``/``conv2d`` call to a
single admission-time walk.  Inside ``jax.jit`` the two compile to the
same HLO, so the win shows up in (a) TRACE time — every Python-level
engine call runs during tracing, for every new shape bucket — and
(b) steady-state EAGER dispatch, the mode the tap-based Table-4
analysis and small-batch experimentation run in.

Rows:
  dispatch/bind              one-time plan construction (includes the
                             prequant jax work — the cost you pay once
                             to stop paying the others)
  dispatch/trace_percall     jit-trace a CNN forward, PolicyMap policy
  dispatch/trace_plan        same trace through a bound Plan
  dispatch/resolve_percall   isolated per-call dispatch work: PolicyMap
                             regex resolution + backend support checks
  dispatch/resolve_plan      the bound equivalent: one dict hit
  dispatch/eager_e2e_*       end-to-end eager GEMM for context (the jnp
                             compute dominates; dispatch deltas are in
                             the noise here, which is the point — the
                             steady-state win is trace/resolve time)

Run:  PYTHONPATH=src python -m benchmarks.run dispatch
"""
from __future__ import annotations

import time

import jax

from benchmarks import common
from benchmarks.common import bench_reps, emit, time_call
from repro import engine as EG
from repro.core.policy import BFPPolicy
from repro.engine import PolicyMap
from repro.models.cnn import small


def _trace_us(policy, params, x):
    """Trace (lower) a fresh jit of the cifarnet forward; fresh closure
    each call so jax's jit cache cannot short-circuit the measurement."""
    def f(p, xx):
        return small.cifarnet_apply(p, xx, policy)
    t0 = time.perf_counter()
    jax.jit(f).lower(params, x)
    return (time.perf_counter() - t0) * 1e6


def run():
    key = jax.random.PRNGKey(0)
    params = small.cifarnet_init(key)
    b = 2 if common.SMOKE else 8
    x = jax.random.normal(key, (b, 32, 32, 3))
    pol = BFPPolicy(straight_through=False)
    # a realistic mixed assignment: enough rules that per-call regex
    # resolution does real work at every site
    pm = PolicyMap.of(("^c1$", None),
                      ("^c2$", pol),
                      ("^c3$", pol.with_(l_w=6, l_i=6)),
                      ("^fc1$", pol.with_(l_w=6, l_i=6)),
                      default=pol)

    t0 = time.perf_counter()
    plan = EG.bind(params, pm)
    bind_us = (time.perf_counter() - t0) * 1e6
    emit("dispatch/bind", bind_us, f"sites={len(plan.sites)}")

    reps = 1 if common.SMOKE else 5
    tr_pm = sorted(_trace_us(pm, params, x) for _ in range(reps))[reps // 2]
    tr_plan = sorted(_trace_us(plan, plan.params, x)
                     for _ in range(reps))[reps // 2]
    emit("dispatch/trace_percall", tr_pm, "")
    emit("dispatch/trace_plan", tr_plan,
         f"speedup_vs_percall={tr_pm / tr_plan:.2f}x")

    # isolated per-call dispatch work: exactly what bind hoists out of
    # the hot path (regex rule resolution + registry/support checks vs
    # one dict hit)
    xs = jax.random.normal(key, (4, 32))
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16)) * 0.1
    n = 50 if common.SMOKE else 5000

    def resolve_percall():
        for _ in range(n):
            p = EG.resolve_policy(pm, "fc1")
            EG.select_backend(p, w)

    def resolve_plan():
        for _ in range(n):
            plan.site("fc1")

    iters = bench_reps(warmup=2, iters=9)
    us_pm = time_call(resolve_percall, **iters) / n
    us_plan = time_call(resolve_plan, **iters) / n
    emit("dispatch/resolve_percall", us_pm, f"calls={n}")
    emit("dispatch/resolve_plan", us_plan,
         f"speedup_vs_percall={us_pm / us_plan:.1f}x")

    # end-to-end eager context: same jnp compute either way, so the
    # dispatch delta disappears into execution time (expected ~1.0x)
    m = 5 if common.SMOKE else 50

    # return the outputs so time_call's block_until_ready actually waits
    # on the async-dispatched GEMMs instead of just their enqueue
    def eager_pm():
        return [EG.gemm(xs, w, pm, path="fc1") for _ in range(m)]

    def eager_plan():
        return [plan.gemm(xs, w, path="fc1") for _ in range(m)]

    us_pm = time_call(eager_pm, **iters) / m
    us_plan = time_call(eager_plan, **iters) / m
    emit("dispatch/eager_e2e_percall", us_pm, f"calls={m}")
    emit("dispatch/eager_e2e_plan", us_plan,
         f"ratio_vs_percall={us_pm / us_plan:.2f}x (compute-dominated)")


if __name__ == "__main__":
    run()
