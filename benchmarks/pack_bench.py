"""E18: packed-container byte ratios — fixed-L vs variable-width (ISSUE 10).

Rows (bytes are actual serialized container / checkpoint-dir sizes):

  pack/wire/sparse_grad/fixed   fixed-L=8 wire container, codec us
  pack/wire/sparse_grad/var     variable-width container, codec us
  pack/ckpt/<model>/fixed       format="bfp_packed" dir vs float32 dir
  pack/ckpt/<model>/var         format="bfp_packed_v2" under the
                                precision-searched PolicyMap

The gated quantity is ``bytes_ratio`` = fixed_bytes / variable_bytes per
record (named ``speedup`` because that is the machine-independent ratio
field ``tools/check_bench.py`` floors at baseline x 0.8) plus the
acceptance assert that the variable-width vgg16-reduced checkpoint is
STRICTLY below the fixed-L byte count (i.e. below the pinned 0.26x
float32 ratio of ISSUE 5).  Absolute byte counts are informational only:
they depend on the RNG-drawn params, which may drift across jax
versions, while the fixed/variable ratio on the SAME params does not.

    PYTHONPATH=src python benchmarks/pack_bench.py --smoke --csv pack.csv
    PYTHONPATH=src python benchmarks/pack_bench.py --bench-json bench-pack-ci.json
"""
import argparse
import json
import os
import sys
import tempfile
import time

import jax
import numpy as np

from benchmarks import common
from repro.checkpoint import store
from repro.core.policy import TPU_TILED
from repro.dist import compress
from repro.models.cnn import MODELS
from repro.tune.precision import search_precision

#: serving-mode policy, same as the ISSUE 5 checkpoint pin in
#: tests/test_packed.py: whole-K tiles, inference numerics.
POL = TPU_TILED.with_(block_k=None, straight_through=False)


def _dir_bytes(d):
    return sum(os.path.getsize(os.path.join(r, f))
               for r, _, fs in os.walk(d) for f in fs)


def _host_us(fn, *args):
    """Median microseconds for a host-side (numpy codec) call."""
    reps = common.bench_reps()
    for _ in range(reps["warmup"]):
        fn(*args)
    ts = []
    for _ in range(reps["iters"]):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def _scenario_wire():
    """Top-k-sparsified gradient leaf on the dist wire: zeroed blocks
    collapse to 1-bit mantissas under the variable codec, so the wire
    container shrinks below fixed-L even after the width-plane header."""
    import jax.numpy as jnp

    n = 4096 if common.SMOKE else 1 << 16
    rng = np.random.default_rng(0)
    g = rng.standard_normal(n).astype(np.float32)
    k = n // 10                               # keep the top 10% by |g|
    g[np.argpartition(np.abs(g), n - k)[: n - k]] = 0.0
    leaf = jnp.asarray(g)

    p_fix = compress.pack_leaf(leaf, 8, 16)
    p_var = compress.pack_leaf(leaf, 8, 16, variable=True)
    us_fix = _host_us(compress.pack_leaf, leaf, 8, 16)
    us_var = _host_us(lambda: compress.pack_leaf(leaf, 8, 16,
                                                 variable=True))
    ratio = p_fix.nbytes / p_var.nbytes
    common.emit("pack/wire/sparse_grad/fixed", us_fix,
                f"nbytes={p_fix.nbytes}")
    common.emit("pack/wire/sparse_grad/var", us_var,
                f"nbytes={p_var.nbytes} bytes_ratio={ratio:.3f}")
    np.testing.assert_array_equal(
        np.asarray(compress.unpack_leaf(p_fix)),
        np.asarray(compress.unpack_leaf(p_var)))
    common.add_record({"kind": "pack", "name": "wire/sparse_grad",
                       "speedup": ratio,
                       "sparsity": 1 - k / n, "bits": 8, "block": 16})


def _scenario_ckpt():
    """float32 vs fixed-L=8 vs precision-searched variable-width
    checkpoint directory bytes (the ISSUE 10 acceptance)."""
    model = "lenet" if common.SMOKE else "vgg16"
    budget, tol, batch = ((5e-2, 0.5, 2) if common.SMOKE
                          else (3e-2, 0.25, 8))
    res = search_precision(model, seed=0, batch=batch, nsr_budget=budget,
                           top1_tol=tol, verbose=not common.SMOKE)
    params = MODELS[model].init(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        store.save(os.path.join(d, "f32"), 0, params)
        store.save(os.path.join(d, "fix"), 0, params,
                   format="bfp_packed", policy=POL, tree_kind="cnn")
        store.save(os.path.join(d, "var"), 0, params,
                   format="bfp_packed_v2", policy=res.policy_map,
                   tree_kind="cnn")
        b_f32 = _dir_bytes(os.path.join(d, "f32", "step_00000000"))
        b_fix = _dir_bytes(os.path.join(d, "fix", "step_00000000"))
        b_var = _dir_bytes(os.path.join(d, "var", "step_00000000"))

    fixed_ratio = b_fix / b_f32
    var_ratio = b_var / b_f32
    widths = ",".join(f"{p}={l}" for p, l in sorted(res.assignment.items()))
    common.emit(f"pack/ckpt/{model}/fixed", 0.0,
                f"bytes={b_fix} ratio_vs_f32={fixed_ratio:.4f}")
    common.emit(f"pack/ckpt/{model}/var", 0.0,
                f"bytes={b_var} ratio_vs_f32={var_ratio:.4f} l_w:{widths}")
    common.add_record({"kind": "pack", "name": f"ckpt/{model}",
                       "speedup": b_fix / b_var,
                       "fixed_ratio_vs_f32": round(fixed_ratio, 4),
                       "var_ratio_vs_f32": round(var_ratio, 4),
                       "l_w": dict(sorted(res.assignment.items())),
                       "top1_agreement": res.top1_agreement})
    if not common.SMOKE and b_var >= b_fix:
        raise SystemExit(
            f"ACCEPTANCE FAIL: variable-width {model} checkpoint "
            f"({b_var} B, {var_ratio:.4f}x f32) is not strictly below "
            f"the fixed-L one ({b_fix} B, {fixed_ratio:.4f}x f32)")


def run():
    _scenario_wire()
    _scenario_ckpt()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.pack_bench")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--csv", metavar="PATH")
    ap.add_argument("--bench-json", metavar="PATH")
    args = ap.parse_args(argv)
    common.set_smoke(args.smoke)
    fh = open(args.csv, "w") if args.csv else None
    common.set_csv(fh)
    records: list = []
    common.set_json(records)
    print("name,us_per_call,derived")
    if fh:
        fh.write("name,us_per_call,derived\n")
    run()
    if fh:
        fh.close()
    if args.bench_json:
        doc = {"schema": "pack-1",
               "mode": "smoke" if args.smoke else "full",
               "records": records}
        with open(args.bench_json, "w") as jf:
            json.dump(doc, jf, indent=1, sort_keys=True)
            jf.write("\n")
        print(f"# wrote {len(records)} records to {args.bench_json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
