"""Paper Table 1 — storage cost of the four block-formatting schemes.

Reports, for the paper's worked example (VGG-16 conv1_1: M=64, K=9,
N=50176) and a transformer layer (d=4096 -> 4096), the average stored
bits per element and the number of block exponents, plus MEASURED packed
sizes from the actual BFPBlock tensors.
"""
from __future__ import annotations

import jax

from repro.core import bfp
from repro.core.bfp import Scheme
from benchmarks.common import emit


def _measured_bits(blk: bfp.BFPBlock, exp_bits: int = 8) -> float:
    mant_bits = 8 if blk.bits <= 8 else 16
    total = blk.mantissa.size * mant_bits + blk.exponent.size * exp_bits
    return total / blk.mantissa.size


def run():
    cases = [("vgg16_conv1_1", 64, 9, 50176), ("transformer_4k", 4096, 4096, 4096)]
    key = jax.random.PRNGKey(0)
    for name, m, k, n in cases:
        w = jax.random.normal(key, (min(m, 512), min(k, 512)))
        for scheme in (Scheme.EQ2, Scheme.EQ3, Scheme.EQ4, Scheme.EQ5,
                       Scheme.TILED):
            nbe = bfp.num_block_exponents(scheme, m, k, n, block_k=128)
            if scheme in (Scheme.EQ2, Scheme.EQ5):
                block_elems_w = m * k
            elif scheme is Scheme.TILED:
                block_elems_w = min(k, 128)
            else:
                block_elems_w = k
            al_w = bfp.average_bits_per_element(8, 8, block_elems_w)
            blk = bfp.bfp_quantize_matrix(w, 8, "w", scheme, block_k=min(
                128, w.shape[1]))
            emit(f"table1/{name}/{scheme.value}", 0.0,
                 f"NBE={nbe};AL_W_analytic={al_w:.3f};"
                 f"AL_W_measured={_measured_bits(blk):.3f}")


if __name__ == "__main__":
    run()
