"""Paper Table 1 — storage cost of the four block-formatting schemes.

Reports, for the paper's worked example (VGG-16 conv1_1: M=64, K=9,
N=50176) and a transformer layer (d=4096 -> 4096), the average stored
bits per element and the number of block exponents — and, since ISSUE 5,
MEASURED ON-DISK BYTES: each scheme's operand is actually quantized,
bit-packed into a ``core.packed.PackedBFP`` container, and its
serialized size compared against the float32 ``.npz`` of the same
matrix.  A final section saves a real vgg16-reduced checkpoint both ways
(``checkpoint.store`` float32 vs ``format="bfp_packed"``) and reports
the artifact ratio, so the Table-1 claim is verified end-to-end on
bytes, not modeled.
"""
from __future__ import annotations

import io
import os
import tempfile

import jax
import numpy as np

from repro.core import bfp, packed
from repro.core.bfp import Scheme
from benchmarks import common
from benchmarks.common import emit


def _measured_bits(blk: bfp.BFPBlock, exp_bits: int = 8) -> float:
    mant_bits = 8 if blk.bits <= 8 else 16
    total = blk.mantissa.size * mant_bits + blk.exponent.size * exp_bits
    return total / blk.mantissa.size


def _npz_bytes(arr: np.ndarray) -> int:
    buf = io.BytesIO()
    np.savez(buf, w=arr)
    return buf.getbuffer().nbytes


def _dir_bytes(d: str) -> int:
    return sum(os.path.getsize(os.path.join(r, f))
               for r, _, fs in os.walk(d) for f in fs)


def _checkpoint_rows():
    """Measured artifact bytes for a real model checkpoint, both formats."""
    from repro.checkpoint import store
    from repro.core.policy import TPU_TILED
    from repro.models.cnn import MODELS

    spec = MODELS["lenet" if common.SMOKE else "vgg16"]
    params = spec.init(jax.random.PRNGKey(0))
    pol = TPU_TILED.with_(block_k=None)   # whole-K tiles: any conv K packs
    with tempfile.TemporaryDirectory() as d:
        store.save(os.path.join(d, "f32"), 0, params)
        store.save(os.path.join(d, "bfp"), 0, params, format="bfp_packed",
                   policy=pol)
        f32 = _dir_bytes(os.path.join(d, "f32", "step_00000000"))
        bfp_b = _dir_bytes(os.path.join(d, "bfp", "step_00000000"))
    emit(f"table1/checkpoint/{spec.name}-reduced", 0.0,
         f"npz_bytes={f32};packed_bytes={bfp_b};"
         f"ratio={bfp_b / f32:.3f};l_w=8")


def run():
    cases = [("vgg16_conv1_1", 64, 9, 50176), ("transformer_4k", 4096, 4096, 4096)]
    key = jax.random.PRNGKey(0)
    for name, m, k, n in cases:
        w = jax.random.normal(key, (min(m, 512), min(k, 512)))
        w_np = np.asarray(w)
        npz = _npz_bytes(w_np)
        for scheme in (Scheme.EQ2, Scheme.EQ3, Scheme.EQ4, Scheme.EQ5,
                       Scheme.TILED):
            nbe = bfp.num_block_exponents(scheme, m, k, n, block_k=128)
            if scheme in (Scheme.EQ2, Scheme.EQ5):
                block_elems_w = m * k
            elif scheme is Scheme.TILED:
                block_elems_w = min(k, 128)
            else:
                block_elems_w = k
            al_w = bfp.average_bits_per_element(8, 8, block_elems_w)
            blk = bfp.bfp_quantize_matrix(w, 8, "w", scheme, block_k=min(
                128, w.shape[1]))
            # the byte-real container: mantissas bit-packed at L=8, one
            # int8 exponent per block, measured against the f32 npz of
            # the same matrix (analytic vs measured side by side)
            pk = packed.pack_block(blk, scheme=scheme.value)
            emit(f"table1/{name}/{scheme.value}", 0.0,
                 f"NBE={nbe};AL_W_analytic={al_w:.3f};"
                 f"AL_W_measured={_measured_bits(blk):.3f};"
                 f"packed_bytes={pk.nbytes};npz_bytes={npz};"
                 f"disk_ratio={pk.nbytes / npz:.3f}")
    _checkpoint_rows()


if __name__ == "__main__":
    run()
