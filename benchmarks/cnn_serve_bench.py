"""E14 CNN serving benchmark — requests/sec vs batch bucket size, and
prequant on/off (ISSUE 4 acceptance: batched throughput >= 2x
single-request on at least one paper-model shape).

Rows:
  cnn_serve/<model>/bucket<b>[/<variant>]   us per REQUEST at bucket b
  cnn_serve/<model>/speedup                 batched vs single-request
  cnn_serve/<model>/prequant                prequant-on vs off at max bucket

The engine is identical across rows — only the bucket geometry (and the
bind-time ``prequantize`` flag for the prequant row) changes, so the
ratio isolates batching/coalescing, not model differences.
"""
from __future__ import annotations

import time

import jax

from benchmarks import common
from repro import engine as EG
from repro.core.policy import PAPER_DEFAULT
from repro.models.cnn import MODELS
from repro.serve.cnn import CnnServeEngine, ImageRequest

POLICY = PAPER_DEFAULT.with_(straight_through=False)


def _throughput(plan, spec, n_req: int, bucket: int, reps: int) -> float:
    """Median requests/sec serving ``n_req`` requests at one bucket size."""
    imgs = [jax.random.normal(jax.random.PRNGKey(10 + i),
                              spec.input_shape()) for i in range(n_req)]

    def serve_once():
        eng = CnnServeEngine(None, spec.apply, plan, slots=bucket,
                             buckets=(bucket,))
        for i, im in enumerate(imgs):
            eng.submit(ImageRequest(rid=i, image=im))
        eng.run()

    serve_once()                      # compile the bucket off the clock
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        serve_once()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return n_req / ts[len(ts) // 2]


def run():
    models = ("vgg16",) if common.SMOKE else ("vgg16", "resnet18")
    n_req = 4 if common.SMOKE else 16
    buckets = (1, 4) if common.SMOKE else (1, 4, 8)
    reps = 1 if common.SMOKE else 3

    for name in models:
        spec = MODELS[name]
        params = spec.init(jax.random.PRNGKey(0))
        plan = EG.bind(params, POLICY, tree="cnn")
        rps = {}
        for b in buckets:
            rps[b] = _throughput(plan, spec, n_req, b, reps)
            common.emit(f"cnn_serve/{name}/bucket{b}", 1e6 / rps[b],
                        f"req_s={rps[b]:.1f}")
        speedup = rps[max(buckets)] / rps[min(buckets)]
        common.emit(f"cnn_serve/{name}/speedup", 0.0,
                    f"batched_vs_single={speedup:.2f}x")

        # prequant on/off at the max bucket: same plan geometry, weights
        # re-quantized per forward instead of once at bind
        plan_off = EG.bind(params, POLICY, tree="cnn", prequantize=False)
        rps_off = _throughput(plan_off, spec, n_req, max(buckets), reps)
        common.emit(f"cnn_serve/{name}/bucket{max(buckets)}/noprequant",
                    1e6 / rps_off, f"req_s={rps_off:.1f}")
        common.emit(f"cnn_serve/{name}/prequant", 0.0,
                    f"prequant_speedup={rps[max(buckets)] / rps_off:.2f}x")


if __name__ == "__main__":
    common.set_smoke(False)
    print("name,us_per_call,derived")
    run()
