"""Paper Table 3 — accuracy drop over the (L_W x L_I) mantissa grid,
without retraining, plus rounding-vs-truncation (paper §3.1 claim, E5).
"""
from __future__ import annotations

from repro.core.bfp import Rounding
from repro.core.policy import BFPPolicy
from benchmarks import common
from benchmarks.common import emit
from benchmarks.cnn_train import accuracy, train_model


def run():
    grids = {"mnist": (3, 4, 5, 6), "cifar": (5, 6, 7, 8)}
    if common.SMOKE:
        grids = {"mnist": (4, 6)}
    steps = 20 if common.SMOKE else 250
    for kind, bits in grids.items():
        params, apply_fn, ev = train_model(kind, steps=steps)
        acc_f = accuracy(params, apply_fn, ev, None)
        emit(f"table3/{kind}/float", 0.0, f"top1={acc_f:.4f}")
        for lw in bits:
            for li in bits:
                pol = BFPPolicy(l_w=lw, l_i=li, straight_through=False)
                acc = accuracy(params, apply_fn, ev, pol)
                emit(f"table3/{kind}/LW{lw}_LI{li}", 0.0,
                     f"drop={acc_f - acc:+.4f}")
        # E5: truncation vs rounding at the mid bit-width
        mid = bits[len(bits) // 2]
        for rnd in (Rounding.ROUND, Rounding.TRUNCATE):
            pol = BFPPolicy(l_w=mid, l_i=mid, rounding=rnd,
                            straight_through=False)
            acc = accuracy(params, apply_fn, ev, pol)
            emit(f"table3/{kind}/round_vs_trunc/{rnd.value}", 0.0,
                 f"L={mid};drop={acc_f - acc:+.4f}")


if __name__ == "__main__":
    run()
