"""E10 — K-tile block-size NSR ablation (the TPU-native generalization,
DESIGN.md §2): SNR vs block_k for fixed 8-bit mantissas."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bfp import Scheme
from repro.core.nsr import snr_db
from repro.core.bfp_dot import bfp_matmul_2d
from repro.core.policy import BFPPolicy
from benchmarks import common
from benchmarks.common import emit


def run():
    key = jax.random.PRNGKey(0)
    b, k, n = (32, 512, 32) if common.SMOKE else (256, 2048, 256)
    x = jax.random.normal(key, (b, k)) * \
        jnp.exp(jax.random.normal(jax.random.PRNGKey(1), (b, k)))
    w = jax.random.normal(jax.random.PRNGKey(2), (k, n)) * 0.05
    ref = x @ w
    p0 = BFPPolicy(scheme=Scheme.EQ4, straight_through=False)
    emit("blocksize/eq4_paper", 0.0,
         f"snr_db={float(snr_db(ref, bfp_matmul_2d(x, w, p0))):.2f}")
    for bk in ((512, 128) if common.SMOKE else (2048, 512, 256, 128, 32)):
        p = BFPPolicy(scheme=Scheme.TILED, block_k=bk,
                      straight_through=False)
        s = float(snr_db(ref, bfp_matmul_2d(x, w, p)))
        # exponent storage overhead per element (8-bit exponents)
        ov = 8.0 / bk
        emit(f"blocksize/tiled_{bk}", 0.0,
             f"snr_db={s:.2f};exp_overhead_bits={ov:.3f}")


if __name__ == "__main__":
    run()
