"""Shared CNN training on the synthetic image tasks (benchmarks E2/E3).

Trains LeNet ('mnist' column) / CifarNet ('cifar10' column) in float32,
then the paper's experiments evaluate the SAME trained weights under BFP
at various mantissa widths — no retraining, exactly the paper's protocol.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.data.pipeline import image_batch
from repro.models.cnn import small
from repro.optim import optimizers as opt


def train_model(kind: str = "mnist", steps: int = 250, batch: int = 64,
                seed: int = 0):
    """Returns (params, apply_fn, eval_set) with float-trained weights."""
    key = jax.random.PRNGKey(seed)
    if kind == "mnist":
        init_fn, apply_fn, hw, ch = small.lenet_init, small.lenet_apply, 28, 1
    else:
        init_fn, apply_fn, hw, ch = (small.cifarnet_init,
                                     small.cifarnet_apply, 32, 3)
    params = init_fn(key)
    opt_state = opt.adamw_init(params)
    _, _, templates = image_batch(jax.random.PRNGKey(1234), 10, 2, hw, ch)

    def loss_fn(p, x, y):
        logits = apply_fn(p, x, None)
        onehot = jax.nn.one_hot(y, 10)
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))

    @jax.jit
    def step(p, o, x, y, lr):
        loss, g = jax.value_and_grad(loss_fn)(p, x, y)
        g, _ = opt.clip_by_global_norm(g, 1.0)
        p, o = opt.adamw_update(g, o, p, lr, weight_decay=1e-4)
        return p, o, loss

    sched = opt.cosine_schedule(2e-3, 20, steps)
    for i in range(steps):
        x, y, _ = image_batch(jax.random.fold_in(key, i), 10, batch, hw, ch,
                              templates)
        params, opt_state, loss = step(params, opt_state, x, y,
                                       sched(jnp.asarray(i)))

    ex, ey, _ = image_batch(jax.random.PRNGKey(999), 10, 512, hw, ch,
                            templates)
    return params, apply_fn, (ex, ey)


def accuracy(params, apply_fn, eval_set, policy) -> float:
    x, y = eval_set
    logits = apply_fn(params, x, policy)
    return float(jnp.mean(jnp.argmax(logits, -1) == y))
