"""CNN training benchmarks: float-baseline helper + BFP train-to-accuracy.

Two layers:

  * :func:`train_model` / :func:`accuracy` — the original E2/E3 helper:
    trains LeNet ('mnist') / CifarNet ('cifar10') in float32, then the
    paper's experiments evaluate the SAME trained weights under BFP at
    various mantissa widths — no retraining, exactly the paper's
    protocol.  table2_scheme / table3_sweep import these; keep them.

  * :func:`run` — E16 (ISSUE 8): train-to-accuracy ON the BFP datapath.
    Forward AND backward GEMMs run block-formatted (``repro.grad``
    custom VJPs, ``straight_through=False``) at L = 4..12, gradients are
    exchanged data-parallel over the compressed packed wire with error
    feedback (``repro.train.cnn``), and each run reports its loss curve,
    final accuracy vs the float baseline, measured wire bytes (one step
    over the REAL packed containers), and the worst measured backward
    gradient NSR against the ``core.nsr`` bound.  In smoke mode the grid
    shrinks to L in {4, 8} and a few steps, and the suite ASSERTS that
    loss decreases and that every measured gradient NSR is under its
    bound — the train-smoke CI gate.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core.policy import BFPPolicy
from repro.data.pipeline import image_batch
from repro.models.cnn import small
from repro.optim import optimizers as opt
from repro.train import cnn as TC


def train_model(kind: str = "mnist", steps: int = 250, batch: int = 64,
                seed: int = 0):
    """Returns (params, apply_fn, eval_set) with float-trained weights."""
    key = jax.random.PRNGKey(seed)
    if kind == "mnist":
        init_fn, apply_fn, hw, ch = small.lenet_init, small.lenet_apply, 28, 1
    else:
        init_fn, apply_fn, hw, ch = (small.cifarnet_init,
                                     small.cifarnet_apply, 32, 3)
    params = init_fn(key)
    opt_state = opt.adamw_init(params)
    _, _, templates = image_batch(jax.random.PRNGKey(1234), 10, 2, hw, ch)

    def loss_fn(p, x, y):
        logits = apply_fn(p, x, None)
        onehot = jax.nn.one_hot(y, 10)
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))

    @jax.jit
    def step(p, o, x, y, lr):
        loss, g = jax.value_and_grad(loss_fn)(p, x, y)
        g, _ = opt.clip_by_global_norm(g, 1.0)
        p, o = opt.adamw_update(g, o, p, lr, weight_decay=1e-4)
        return p, o, loss

    sched = opt.cosine_schedule(2e-3, 20, steps)
    for i in range(steps):
        x, y, _ = image_batch(jax.random.fold_in(key, i), 10, batch, hw, ch,
                              templates)
        params, opt_state, loss = step(params, opt_state, x, y,
                                       sched(jnp.asarray(i)))

    ex, ey, _ = image_batch(jax.random.PRNGKey(999), 10, 512, hw, ch,
                            templates)
    return params, apply_fn, (ex, ey)


def accuracy(params, apply_fn, eval_set, policy) -> float:
    x, y = eval_set
    logits = apply_fn(params, x, policy)
    return float(jnp.mean(jnp.argmax(logits, -1) == y))


# ---------------------------------------------------------------------------
# E16: BFP train-to-accuracy (quantized backward + compressed exchange)
# ---------------------------------------------------------------------------

def _train_one(policy, steps: int, lr: float, batch: int,
               grad_bits, measure_nsr: bool):
    cfg = TC.CnnTrainConfig(model="cifarnet", workers=2, batch=batch,
                            lr=lr, policy=policy, grad_bits=grad_bits)
    return cfg, TC.train_cnn(
        cfg, steps=steps,
        eval_batch=128 if common.SMOKE else 512,
        measure_nsr_every=steps if measure_nsr else 0,  # once, at step 0
        packed_wire_steps=1 if grad_bits is not None else 0)


def run() -> None:
    """Emit train-to-accuracy rows; assert the smoke training contract."""
    smoke = common.SMOKE
    steps = 8 if smoke else 60
    batch = 16 if smoke else 64
    lr = 1e-3
    widths = (4, 8) if smoke else (4, 6, 8, 10, 12)

    _, ref = _train_one(None, steps, lr, batch, None, False)
    ref_losses = [h["loss"] for h in ref["history"]]
    common.emit("cnn_train/float/loss", ref_losses[-1],
                f"first={ref_losses[0]:.4f} last={ref_losses[-1]:.4f} "
                f"steps={steps}")
    common.emit("cnn_train/float/accuracy", ref["accuracy"],
                f"acc={ref['accuracy']:.4f} baseline")
    if smoke:
        assert ref_losses[-1] < ref_losses[0], \
            f"float loss did not decrease: {ref_losses[0]:.4f} -> " \
            f"{ref_losses[-1]:.4f}"

    for L in widths:
        pol = BFPPolicy(l_w=L, l_i=L, straight_through=False)
        cfg, out = _train_one(pol, steps, lr, batch, 8, True)
        losses = [h["loss"] for h in out["history"]]
        tag = f"cnn_train/L{L}"
        common.emit(f"{tag}/loss", losses[-1],
                    f"first={losses[0]:.4f} last={losses[-1]:.4f} "
                    f"steps={steps}")
        common.emit(f"{tag}/accuracy", out["accuracy"],
                    f"acc={out['accuracy']:.4f} "
                    f"float={ref['accuracy']:.4f} "
                    f"drop={ref['accuracy'] - out['accuracy']:.4f}")
        wire = out["wire_bytes"]
        common.emit(f"{tag}/wire_bytes", wire["measured_bytes"],
                    f"float_per_step={wire['float_per_step_bytes']} "
                    f"ratio={wire['ratio']:.4f}")
        recs = out["nsr_records"]
        bounded = [r for r in recs if r.eta_bound != float("inf")]
        worst = max((r.eta_measured / r.eta_bound for r in bounded),
                    default=0.0)
        common.emit(f"{tag}/grad_nsr_frac_of_bound", worst,
                    f"frac={worst:.3e} n_backward_gemms={len(recs)}")
        if smoke:
            assert losses[-1] < losses[0], \
                f"L={L} loss did not decrease: {losses[0]:.4f} -> " \
                f"{losses[-1]:.4f}"
            bad = [r for r in recs if not r.within_bound]
            assert not bad, f"L={L} gradient NSR over bound: " \
                            f"{[(r.path, r.kind) for r in bad]}"


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(prog="benchmarks.cnn_train")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid + training-contract assertions")
    args = ap.parse_args()
    common.set_smoke(args.smoke)
    print("name,us_per_call,derived")
    run()
