"""Paper Table 2 — block-partition scheme (eq. 2) vs (eq. 4) vs float.

The paper measures VGG-16 top-1/top-5 on ILSVRC12; offline we run the
same protocol on the in-repo trained CNNs (DESIGN.md §8.1): float-trained
weights evaluated under each scheme WITHOUT retraining.
"""
from __future__ import annotations

from repro.core.bfp import Scheme
from repro.core.policy import BFPPolicy
from benchmarks import common
from benchmarks.common import emit
from benchmarks.cnn_train import accuracy, train_model


def run():
    kinds = ("mnist",) if common.SMOKE else ("mnist", "cifar")
    steps = 20 if common.SMOKE else 250
    for kind in kinds:
        params, apply_fn, ev = train_model(kind, steps=steps)
        acc_f = accuracy(params, apply_fn, ev, None)
        emit(f"table2/{kind}/float", 0.0, f"top1={acc_f:.4f}")
        # TILED needs block_k | K; conv K=25 here — covered by the
        # blocksize ablation (E10) on clean dims instead.
        schemes = ((Scheme.EQ2, Scheme.EQ4) if common.SMOKE else
                   (Scheme.EQ2, Scheme.EQ4, Scheme.EQ3, Scheme.EQ5))
        for scheme in schemes:
            pol = BFPPolicy(scheme=scheme, straight_through=False)
            acc = accuracy(params, apply_fn, ev, pol)
            emit(f"table2/{kind}/{scheme.value}", 0.0,
                 f"top1={acc:.4f};drop={acc_f - acc:+.4f}")


if __name__ == "__main__":
    run()
