"""Engine throughput: cached pre-quantized weights vs per-step requant.

The paper's deployment stores weights block-formatted in HBM; the engine
mirrors that with the ``{"m", "s"}`` wire format.  This bench measures
what that buys on the emulated datapath: an inference-shaped GEMM
(small batch, large weight) where per-forward weight re-quantization is
a significant fraction of the work.

Rows:
  engine/requant_each_step   float weights, quantized inside every call
  engine/cached_prequant     int8+scale weights, quantized once offline
  engine/float_baseline      no quantization (reference)
  engine/lenet_requant|prequant  the same effect through a whole CNN

Run:  PYTHONPATH=src python -m benchmarks.run engine
"""
from __future__ import annotations

import jax

from benchmarks import common
from benchmarks.common import bench_reps, emit, time_call
from repro import engine as EG
from repro.core.bfp import Scheme
from repro.core.policy import BFPPolicy
from repro.core.prequant import prequant_leaf


def run():
    key = jax.random.PRNGKey(0)
    # decode-like: weight >> activations
    b, k, n = (8, 512, 512) if common.SMOKE else (8, 2048, 2048)
    x = jax.random.normal(key, (b, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.05
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=128,
                    straight_through=False)
    pq = prequant_leaf(w, pol)
    flops = 2 * b * k * n

    f_float = jax.jit(lambda x, w: EG.gemm(x, w, None))
    f_req = jax.jit(lambda x, w: EG.gemm(x, w, pol))
    f_pre = jax.jit(lambda x, m, s: EG.gemm(x, {"m": m, "s": s}, pol))

    iters = bench_reps(warmup=3, iters=15)  # medians over enough reps
    us_float = time_call(f_float, x, w, **iters)
    us_req = time_call(f_req, x, w, **iters)
    us_pre = time_call(f_pre, x, pq["m"], pq["s"], **iters)
    emit("engine/float_baseline", us_float, f"GFLOPs={flops/us_float/1e3:.1f}")
    emit("engine/requant_each_step", us_req, f"GFLOPs={flops/us_req/1e3:.1f}")
    emit("engine/cached_prequant", us_pre,
         f"GFLOPs={flops/us_pre/1e3:.1f};speedup_vs_requant="
         f"{us_req / us_pre:.2f}x")

    # whole-model view: LeNet forward, weights quantized per step vs once
    from repro.models.cnn import small
    params = small.lenet_init(jax.random.PRNGKey(2))
    img = jax.random.normal(jax.random.PRNGKey(3), (8, 28, 28, 1))
    eq4 = BFPPolicy(straight_through=False)
    params_pq = EG.prequantize_cnn(params, eq4)
    g_req = jax.jit(lambda p, x: small.lenet_apply(p, x, eq4))
    g_pre = jax.jit(lambda p, x: small.lenet_apply(p, x, eq4))
    us_g_req = time_call(g_req, params, img, **iters)
    us_g_pre = time_call(g_pre, params_pq, img, **iters)
    emit("engine/lenet_requant", us_g_req, "")
    emit("engine/lenet_prequant", us_g_pre,
         f"speedup_vs_requant={us_g_req / us_g_pre:.2f}x")

    # wire-format storage cut (the paper's §3.1 traffic argument)
    f32_bytes = w.size * 4
    wire_bytes = pq["m"].size * 1 + pq["s"].size * 4
    emit("engine/weight_bytes_f32_vs_wire", 0.0,
         f"{f32_bytes}->{wire_bytes};cut={f32_bytes / wire_bytes:.2f}x")


if __name__ == "__main__":
    run()
